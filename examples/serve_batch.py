"""Batched serving demo: the DecodeEngine serving concurrent requests through
the exact and the L2S-screened head, reporting tokens/s and agreement.

Run: PYTHONPATH=src python examples/serve_batch.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import L2SConfig, TrainConfig, get_config
from repro.core import collect_contexts, fit_l2s
from repro.data import ZipfMarkovCorpus, make_lm_batches
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw_init
from repro.serving import DecodeEngine

VOCAB, BATCH, NEW = 3000, 16, 48

cfg = dataclasses.replace(get_config("ptb-small-lstm"), vocab_size=VOCAB,
                          d_model=128, dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.key(0), dtype=jnp.float32)
corpus = ZipfMarkovCorpus(VOCAB, branching=64, seed=0)
tcfg = TrainConfig(lr=2e-3, total_steps=250, warmup_steps=20,
                   remat="none", loss_chunk=None)
step_fn = jax.jit(make_train_step(model, tcfg))
opt = adamw_init(params)
print("training ...")
for batch in make_lm_batches(corpus, 250, 16, 64, seed=1):
    params, opt, _ = step_fn(params, opt,
                             {k: jnp.asarray(v) for k, v in batch.items()})

H, y = collect_contexts(
    model, params,
    [jnp.asarray(b["tokens"]) for b in make_lm_batches(corpus, 30, 16, 64,
                                                       seed=9)],
    max_vectors=20_000)
state = fit_l2s(H, y, VOCAB, L2SConfig(num_clusters=100, budget=150,
                                       outer_iters=2, sgd_steps=150))
engine = DecodeEngine(model, params, screen=state.screen,
                      max_len=16 + NEW)

requests = corpus.sample_batch(BATCH, 16, seed=11)
# warmup compiles — heads are resolved by name and switchable per request
engine.generate(requests, 2, head="exact")
engine.generate(requests, 2, head="screened")

t0 = time.perf_counter()
exact = engine.generate(requests, NEW, head="exact")
t_exact = time.perf_counter() - t0
t0 = time.perf_counter()
fast = engine.generate(requests, NEW, head="screened")
t_fast = time.perf_counter() - t0

agree = float((exact.tokens == fast.tokens).mean())
print(f"exact softmax : {BATCH * NEW / t_exact:8.0f} tok/s")
print(f"L2S screened  : {BATCH * NEW / t_fast:8.0f} tok/s "
      f"({t_exact / t_fast:.2f}x, agreement {agree:.3f})")

# per-request routing: the same engine serves a quality-tier request on the
# exact head and a latency-tier request on the screened head, no re-init
hi = engine.generate(requests[:1], 8, head="exact")
lo = engine.generate(requests[1:2], 8, head="screened")
print(f"per-request routing: exact tier {hi.tokens[0][:6]}..., "
      f"screened tier {lo.tokens[0][:6]}...")
