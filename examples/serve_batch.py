"""Mixed-traffic serving demo: one DecodeEngine, many ServeRequests, a
RoutingPolicy deciding per request which softmax head decodes it.

Run: PYTHONPATH=src python examples/serve_batch.py            # full demo
     PYTHONPATH=src python examples/serve_batch.py --reduced  # CI smoke
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import L2SConfig, TrainConfig, get_config
from repro.core import collect_contexts, fit_l2s
from repro.data import ZipfMarkovCorpus, make_lm_batches
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw_init
from repro.serving import (AdmissionRejected, BudgetAdmission,
                           ContinuousScheduler, CostAwarePolicy,
                           DecodeEngine, ServeRequest, TierPolicy)

ap = argparse.ArgumentParser()
ap.add_argument("--reduced", action="store_true",
                help="tiny model + short decode for CI smoke runs")
args = ap.parse_args()

if args.reduced:
    VOCAB, D, STEPS, BATCH, NEW = 600, 64, 60, 8, 8
else:
    VOCAB, D, STEPS, BATCH, NEW = 3000, 128, 250, 16, 48

cfg = dataclasses.replace(get_config("ptb-small-lstm"), vocab_size=VOCAB,
                          d_model=D, dtype="float32")
model = build_model(cfg)
params = model.init(jax.random.key(0), dtype=jnp.float32)
corpus = ZipfMarkovCorpus(VOCAB, branching=64, seed=0)
tcfg = TrainConfig(lr=2e-3, total_steps=STEPS, warmup_steps=20,
                   remat="none", loss_chunk=None)
step_fn = jax.jit(make_train_step(model, tcfg))
opt = adamw_init(params)
print("training ...")
for batch in make_lm_batches(corpus, STEPS, 16, 64, seed=1):
    params, opt, _ = step_fn(params, opt,
                             {k: jnp.asarray(v) for k, v in batch.items()})

H, y = collect_contexts(
    model, params,
    [jnp.asarray(b["tokens"]) for b in make_lm_batches(corpus, 30, 16, 64,
                                                       seed=9)],
    max_vectors=20_000)
state = fit_l2s(H, y, VOCAB, L2SConfig(num_clusters=100 if not args.reduced
                                       else 16,
                                       budget=150 if not args.reduced else 48,
                                       outer_iters=2, sgd_steps=150))
engine = DecodeEngine(model, params, screen=state.screen,
                      max_len=16 + NEW)

# -- mixed traffic: every request carries its own latency tier / accuracy
#    floor, and the policy resolves each to a head. One engine, one batch.
prompts = corpus.sample_batch(BATCH, 16, seed=11)
requests = []
for i, p in enumerate(prompts):
    if i % 4 == 0:       # quality tier: caller demands exact decode
        requests.append(ServeRequest(prompt=p, max_new=NEW,
                                     latency_tier="batch",
                                     accuracy_floor=1.0))
    elif i % 4 == 1:     # explicit override: escape hatch past the policy
        requests.append(ServeRequest(prompt=p, max_new=NEW, head="exact"))
    else:                # latency tier: cheapest acceptable head
        requests.append(ServeRequest(prompt=p, max_new=NEW,
                                     latency_tier="realtime"))

policy = CostAwarePolicy(["screened", "exact"])
engine.serve_batch(requests, policy=policy)          # warmup compiles
t0 = time.perf_counter()
results = engine.serve_batch(requests, policy=policy)
t_mixed = time.perf_counter() - t0
by_head = {}
for r in results:
    by_head.setdefault(r.head, []).append(r)
total_tokens = sum(len(r.tokens) for r in results)
print(f"mixed batch : {total_tokens / t_mixed:8.0f} tok/s over "
      f"{len(results)} requests -> "
      + ", ".join(f"{k}×{len(v)}" for k, v in sorted(by_head.items())))

# routed results agree with solo exact decode on most tokens
agree = np.mean([
    (r.tokens == engine.generate(r.request.prompt[None], r.request.max_new,
                                 head="exact").tokens[0]).mean()
    for r in results])
print(f"agreement vs exact: {agree:.3f}  "
      f"(screened requests trade a little fidelity for speed)")

# same engine still answers tier-mapped traffic with zero new compiles
tier_policy = TierPolicy({"realtime": "screened", "batch": "exact"},
                         default="screened")
res2 = engine.serve_batch(requests, policy=tier_policy)
print(f"tier policy routes: "
      + ", ".join(sorted({r.head for r in res2}))
      + f"; cached steps: {engine._cache_size()}")

# -- continuous batching: the same traffic as a live stream ------------------
#    Requests are submitted one at a time; the scheduler admits each against
#    a flops budget from the head catalog (over-budget arrivals come back as
#    typed AdmissionRejected results — here the budget is roomy), joins them
#    into running fixed-width decode streams at sequence boundaries, and
#    retires them as they finish. Greedy tokens are bit-identical to the
#    serve_batch results above.
catalog = engine.head_catalog(("screened", "exact"))
sched = ContinuousScheduler(
    engine, policy=tier_policy,
    admission=BudgetAdmission(
        flops_budget=8 * max(m["flops_per_query"] for m in catalog.values())),
    max_slots=4)
t0 = time.perf_counter()
res3 = sched.serve(requests)
t_sched = time.perf_counter() - t0
snap = sched.stats.snapshot()
served = [r for r in res3 if not isinstance(r, AdmissionRejected)]
for r2, r3 in zip(res2, res3):
    if isinstance(r3, AdmissionRejected) or r3.request.temperature is not None:
        continue
    if r3.head == r2.head:                # admission may have downgraded
        assert np.array_equal(r2.tokens, r3.tokens)   # continuous == batch
print(f"scheduler   : {snap['tokens'] / t_sched:8.0f} tok/s over "
      f"{len(served)} requests (admitted {snap['admitted']}, rejected "
      f"{snap['rejected']}, downgraded {snap['downgraded']}); "
      f"p50 latency {snap['latency']['p50_s'] * 1e3:.0f}ms, "
      f"p95 {snap['latency']['p95_s'] * 1e3:.0f}ms; "
      f"cached steps: {engine._cache_size()}")
