"""Pallas kernel demo: the TPU-adapted screened softmax hot path
(cluster_route → scalar-prefetch block gather-matmul → subset top-k),
validated against the pure-jnp reference in interpret mode.

Run: PYTHONPATH=src python examples/kernel_demo.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.screening import ScreenParams, screened_topk
from repro.kernels.ops import pack_head_blocks, screened_topk_tpu
from repro.kernels.ref import cluster_route_ref
from repro.kernels.route import cluster_route_pallas

rng = np.random.default_rng(0)
L, d, r, K, B = 16_384, 512, 64, 8, 32          # vocab, dim, clusters, blocks
print(f"softmax head: vocab={L}, d={d} | screen: r={r}, {K} blocks/cluster")

W = jnp.asarray(rng.standard_normal((L, d)), jnp.float32)
b = jnp.asarray(rng.standard_normal((L,)) * 0.1, jnp.float32)
Wb, bb = pack_head_blocks(W, b)                  # (128, 128, 512) MXU tiles
print(f"packed head: {Wb.shape} — {Wb.nbytes/1e6:.0f} MB in vocab blocks")

v = jnp.asarray(rng.standard_normal((r, d)), jnp.float32)
cand = jnp.asarray(rng.integers(0, Wb.shape[0], (r, K)), jnp.int32)
h = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)

ids, vals = screened_topk_tpu(Wb, bb, v, cand, h, k=5)     # kernels (interpret)
route = cluster_route_pallas(h, v)
assert bool(jnp.all(route == cluster_route_ref(h, v)))

sp = ScreenParams(v=v, cand_idx=cand,
                  cand_len=jnp.full((r,), K, jnp.int32), vocab_size=L,
                  block=128)
ids_ref, vals_ref = screened_topk(W, b, sp, h, 5)          # pure jnp
assert bool(jnp.all(ids == ids_ref)), "kernel != reference"
print("kernel path == jnp reference on all", B, "queries  ✓")
print("per-query compute: full softmax", L * d, "MACs vs screened",
      r * d + K * 128 * d, f"MACs  ({L * d / (r * d + K * 128 * d):.1f}x fewer)")
