"""Pallas kernel demo: the TPU-adapted screened softmax hot path
(cluster_route → scalar-prefetch block gather-matmul → subset top-k) behind
the ``SoftmaxHead`` protocol, validated against the pure-jnp reference head
in interpret mode.

Run: PYTHONPATH=src python examples/kernel_demo.py
"""
import jax.numpy as jnp
import numpy as np

from repro import heads
from repro.core.screening import ScreenParams
from repro.kernels.ref import cluster_route_ref
from repro.kernels.route import cluster_route_pallas

rng = np.random.default_rng(0)
L, d, r, K, B = 16_384, 512, 64, 8, 32          # vocab, dim, clusters, blocks
print(f"softmax head: vocab={L}, d={d} | screen: r={r}, {K} blocks/cluster")

W = jnp.asarray(rng.standard_normal((L, d)), jnp.float32)
b = jnp.asarray(rng.standard_normal((L,)) * 0.1, jnp.float32)
v = jnp.asarray(rng.standard_normal((r, d)), jnp.float32)
cand = jnp.asarray(rng.integers(0, -(-L // 128), (r, K)), jnp.int32)
h = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
sp = ScreenParams(v=v, cand_idx=cand,
                  cand_len=jnp.full((r,), K, jnp.int32), vocab_size=L,
                  block=128)

# one registry, two backends over the same screen
kern = heads.get("screened-pallas", W=W, b=b, screen=sp)   # interpret on CPU
ref = heads.get("screened", W=W, b=b, screen=sp)           # pure jnp
print(f"packed head: {kern.packed_shape} — {kern.packed_nbytes/1e6:.0f} MB "
      "in MXU vocab blocks (prepare() ran once)")

ids, vals = kern.topk(h, 5)
route = cluster_route_pallas(h, v)
assert bool(jnp.all(route == cluster_route_ref(h, v)))

ids_ref, vals_ref = ref.topk(h, 5)
assert bool(jnp.all(ids == ids_ref)), "kernel head != reference head"
print("kernel head == jnp reference head on all", B, "queries  ✓")
print("per-query compute (flops_per_query): full softmax",
      f"{heads.get('exact', W=W, b=b).flops_per_query:.0f}",
      "vs screened", f"{kern.flops_per_query:.0f}",
      f"({heads.get('exact', W=W, b=b).flops_per_query / kern.flops_per_query:.1f}x fewer)")
