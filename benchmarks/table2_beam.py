"""Paper Table 2: beam-search decode quality vs speedup. BLEU is replaced by
DECODE AGREEMENT with the exact-softmax beam (token-level + exact-match), per
DESIGN §6 — the quantity BLEU-delta proxies on real NMT data.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_artifacts
from repro.configs import L2SConfig
from repro.core import fit_l2s
from repro.data import ZipfMarkovCorpus
from repro.serving import DecodeEngine

N_PROMPTS = 12
PROMPT_LEN = 12
MAX_NEW = 24


def run():
    cfg, model, params, W, b, Htr, ytr, *_ = get_artifacts()
    state = fit_l2s(Htr[:40_000], ytr[:40_000], cfg.vocab_size,
                    L2SConfig(num_clusters=100, budget=200, outer_iters=2,
                              sgd_steps=200))
    engine = DecodeEngine(model, params, screen=state.screen,
                          max_len=PROMPT_LEN + MAX_NEW)
    c = ZipfMarkovCorpus(cfg.vocab_size, branching=96, seed=0)
    prompts = c.sample_batch(N_PROMPTS, PROMPT_LEN, seed=1234)

    for beam in (1, 5):
        tok_agree, exact_match, t_full, t_l2s = [], [], 0.0, 0.0
        for i in range(N_PROMPTS):
            t0 = time.perf_counter()
            ref = engine.beam_search(prompts[i], beam, MAX_NEW,
                                     head="exact")
            t_full += time.perf_counter() - t0
            t0 = time.perf_counter()
            got = engine.beam_search(prompts[i], beam, MAX_NEW,
                                     head="screened")
            t_l2s += time.perf_counter() - t0
            agree = float((ref.tokens[0] == got.tokens[0]).mean())
            tok_agree.append(agree)
            exact_match.append(float(agree == 1.0))
        us = t_l2s / (N_PROMPTS * MAX_NEW) * 1e6
        csv_row(f"table2/beam{beam}", us,
                f"speedup={t_full / t_l2s:.2f}x,"
                f"token_agreement={np.mean(tok_agree):.3f},"
                f"exact_match={np.mean(exact_match):.2f}")


if __name__ == "__main__":
    run()
