"""Speculative-decoding serving benchmark: accepted-tokens-per-step and
tokens/s for each registered cheap draft head against one exact verify
head, vs plain exact continuous batching.

For every draft head in {screened, screened-pallas, adaptive} that is
buildable in the engine, traffic is served twice through a
``ContinuousScheduler`` whose ``SpecPolicy`` pins that draft: once to warm
the compiled draft/verify steps, once timed. The report per draft head:

  accepted tok/step   emitted tokens / per-slot verify rounds (plain
                      decode scores exactly 1.0 on this metric)
  acceptance          drafted tokens the verify head kept
  tokens/s, speedup   timed drain vs the plain exact baseline
  recompiles          XLA executables added between warmup and the timed
                      run — the headline is that it stays 0: the adaptive
                      draft-length controller shrinks n inside ONE padded
                      verify executable
  parity              greedy spec tokens are BIT-identical to plain exact

With more than one jax device (e.g.
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) the verify head
upgrades to ``exact-sharded``, exercising the mesh-aware batched verify
step; drafting and acceptance are unchanged (sharded verify is greedy-only
by design).

    PYTHONPATH=src python benchmarks/serve_spec.py              # full
    PYTHONPATH=src python benchmarks/serve_spec.py --reduced    # CI smoke

The CI smoke additionally ASSERTS the spec-serving contract: zero
recompiles, acceptance > 0, and bit-parity (see .github/workflows/ci.yml).
Results merge into ``BENCH_serving.json`` under ``serve_spec``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks.common import update_bench_json
    from benchmarks.serve_mixed import build_engine
except ImportError:                      # script's own dir is sys.path[0]
    from common import update_bench_json
    from serve_mixed import build_engine

from repro.serving import (ContinuousScheduler, ServeRequest, ServeResult,
                           SpecPolicy, StaticPolicy)

DRAFTS = ("screened", "screened-pallas", "adaptive")


def _serve_timed(engine, requests, verify, spec=None):
    """One fresh scheduler drain; returns (results, wall seconds, stats)."""
    sched = ContinuousScheduler(engine, policy=StaticPolicy(verify),
                                spec=spec)
    t0 = time.perf_counter()
    results = sched.serve(requests)
    return results, time.perf_counter() - t0, sched.stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=None,
                    help="concurrent requests (default 8 reduced / 24)")
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--draft-len", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable output file ('' disables)")
    args = ap.parse_args(argv)
    n_req = args.requests or (8 if args.reduced else 24)
    max_new = args.max_new or (8 if args.reduced else 32)

    cfg, corpus, engine = build_engine(args.reduced, args.seed)
    verify = "exact-sharded" if jax.device_count() > 1 else "exact"
    prompts = corpus.sample_batch(n_req, 16, seed=42)
    requests = [ServeRequest(prompt=p, max_new=max_new) for p in prompts]

    # plain exact baseline: warm once, time a fresh drain
    _serve_timed(engine, requests, verify)
    base, t_base, _ = _serve_timed(engine, requests, verify)
    base_tokens = {i: r.tokens for i, r in enumerate(base)}
    total_tokens = sum(len(t) for t in base_tokens.values())
    print(f"\n[serve_spec] vocab={cfg.vocab_size} requests={n_req} "
          f"max_new={max_new} draft_len={args.draft_len} "
          f"devices={jax.device_count()} verify={verify}")
    print(f"[serve_spec] baseline {verify}: {total_tokens} tokens in "
          f"{t_base:.2f}s = {total_tokens / t_base:.0f} tok/s")

    catalog = engine.head_catalog(DRAFTS)
    print(f"{'draft':<18}{'acc tok/step':>13}{'acceptance':>11}"
          f"{'tok/s':>9}{'speedup':>8}{'recompiles':>11}{'parity':>7}")
    per_draft = {}
    smoke_ok = True
    for draft in DRAFTS:
        if draft not in catalog:
            print(f"{draft:<18}{'-- not buildable in this engine --':>40}")
            continue
        spec = SpecPolicy(drafts=(draft,), draft_len=args.draft_len)
        _serve_timed(engine, requests, verify, spec=spec)     # warmup
        counts0 = engine.compiled_step_counts()
        results, t_spec, stats = _serve_timed(engine, requests, verify,
                                              spec=spec)
        counts1 = engine.compiled_step_counts()
        recompiles = sum(counts1.values()) - sum(counts0.values())
        parity = all(
            isinstance(r, ServeResult) and
            np.array_equal(r.tokens, base_tokens[i])
            for i, r in enumerate(results))
        sp = stats.snapshot()["spec"] or {}
        # SpecPolicy may decline a draft whose flops advantage over this
        # verify head is too thin (e.g. adaptive vs per-shard exact-sharded
        # flops) — those requests serve plain, which is correct behavior,
        # not a contract violation
        engaged = sp.get("rounds", 0) > 0
        acc_step = sp.get("accepted_tokens_per_step", float("nan"))
        acc_rate = sp.get("draft_acceptance", float("nan"))
        tok_s = total_tokens / t_spec
        if engaged:
            print(f"{draft:<18}{acc_step:>13.2f}{acc_rate:>11.3f}"
                  f"{tok_s:>9.0f}{t_base / t_spec:>8.2f}{recompiles:>11}"
                  f"{str(parity):>7}")
        else:
            note = ("-- policy declined (served plain: flops advantage "
                    "below min_ratio) --")
            print(f"{draft:<18}{note:>58}")
        per_draft[draft] = {
            "engaged": engaged,
            "accepted_tokens_per_step": acc_step,
            "acceptance_rate": acc_rate,
            "accepted": sp.get("accepted", 0),
            "drafted": sp.get("drafted", 0),
            "verify_queries": sp.get("verify_queries", 0),
            "verify_flops": sp.get("verify_flops", 0.0),
            "decode_s": t_spec, "tokens_per_s": tok_s,
            "speedup": t_base / t_spec,
            "recompiles": recompiles, "parity": parity,
        }
        smoke_ok &= parity and recompiles == 0 and \
            (not engaged or sp.get("accepted", 0) > 0)
    if not any(d["engaged"] for d in per_draft.values()):
        print("[serve_spec] no draft head engaged — nothing speculated")
        return 1
    if args.json:
        path = update_bench_json("serve_spec", {
            "devices": jax.device_count(), "vocab": cfg.vocab_size,
            "requests": n_req, "max_new": max_new,
            "draft_len": args.draft_len, "reduced": args.reduced,
            "verify_head": verify,
            "baseline": {"head": verify, "tokens": total_tokens,
                         "decode_s": t_base,
                         "tokens_per_s": total_tokens / t_base},
            "per_draft": per_draft,
        }, path=args.json)
        print(f"[serve_spec] wrote {path}")
    print(f"[serve_spec] contract (parity, 0 recompiles, acceptance>0): "
          f"{'OK' if smoke_ok else 'VIOLATED'}")
    return 0 if smoke_ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
