"""§Roofline deliverable: per (arch × shape × mesh) three-term roofline from
the dry-run artifacts (results/*.jsonl), with MODEL_FLOPS/HLO_FLOPs ratio and
the dominant bottleneck. Emits CSV + a markdown table to results/roofline.md.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import csv_row
from repro.configs import INPUT_SHAPES, get_config

FILES = {
    "16x16": "results/baselines_16x16.jsonl",
    "16x16-l2s": "results/l2s_16x16.jsonl",
    "2x16x16": "results/baselines_2x16x16.jsonl",
    # §Perf-optimized reruns (seq-parallel attention, seq-sharded caches,
    # 2D weight-stationary serving, sharded MoE dispatch buffers)
    "16x16-opt": "results/opt_16x16.jsonl",
    "16x16-opt-l2s": "results/opt_l2s_16x16.jsonl",
    "2x16x16-opt": "results/opt_2x16x16.jsonl",
}


def model_flops_per_dev(arch: str, shape_name: str, n_chips: int) -> float:
    cfg = get_config(arch)
    sc = INPUT_SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if sc.kind == "train":
        tokens = sc.global_batch * sc.seq_len
        return 6.0 * n_active * tokens / n_chips
    if sc.kind == "prefill":
        tokens = sc.global_batch * sc.seq_len
        return 2.0 * n_active * tokens / n_chips
    # decode: one token per sequence
    return 2.0 * n_active * sc.global_batch / n_chips


def load(fname):
    if not os.path.exists(fname):
        return []
    return [json.loads(l) for l in open(fname)]


def run():
    lines = ["# Roofline table (per-device terms, TPU v5e constants)", "",
             "| arch | shape | mesh | head | compute_s | memory_s | "
             "collective_s | dominant | MODEL/HLO flops | note |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for mesh_name, fname in FILES.items():
        head = "l2s" if mesh_name.endswith("l2s") else "full"
        n_chips = 512 if mesh_name.startswith("2x") else 256
        if not os.path.exists(fname):
            continue
        for r in load(fname):
            if "skipped" in r:
                lines.append(f"| {r['arch']} | {r['shape']} | {mesh_name} | "
                             f"{head} | — | — | — | — | — | SKIP: "
                             f"{r['skipped'][:40]} |")
                continue
            if "error" in r or "roofline" not in r or \
                    "error" in r.get("roofline", {}):
                lines.append(f"| {r['arch']} | {r['shape']} | {mesh_name} | "
                             f"{head} | ERR | | | | | |")
                continue
            rl = r["roofline"]
            mf = model_flops_per_dev(r["arch"], r["shape"], n_chips)
            ratio = mf / max(rl["flops_per_dev"], 1.0)
            note = r.get("variant", "")
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh_name} | {head} "
                f"| {rl['compute_s']:.3e} | {rl['memory_s']:.3e} "
                f"| {rl['collective_s']:.3e} | {rl['dominant']} "
                f"| {ratio:.2f} | {note} |")
            csv_row(f"roofline/{r['arch']}/{r['shape']}/{mesh_name}/{head}",
                    rl["memory_s"] * 1e6,
                    f"dominant={rl['dominant']},compute_s={rl['compute_s']:.3e},"
                    f"collective_s={rl['collective_s']:.3e},"
                    f"model_hlo_ratio={ratio:.2f}")
    os.makedirs("results", exist_ok=True)
    with open("results/roofline.md", "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"[roofline] wrote results/roofline.md ({len(lines) - 4} rows)")


if __name__ == "__main__":
    run()
