"""Paper Table 4: end-to-end L2S vs the spherical-kmeans-only screen at the
same budget — isolates the value of the Gumbel-trained clustering."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_artifacts, time_fn
from repro.configs import L2SConfig
from repro.core import fit_l2s, precision_at_k
from repro.core.evaluate import (PerQueryScreen, avg_candidate_size,
                                 exact_topk)
from repro.core.train_l2s import kmeans_only_screen
import time


def run(k: int = 5):
    cfg, model, params, W, b, Htr, ytr, Hte, yte, _ = get_artifacts()
    Wd, bd = jnp.asarray(W), jnp.asarray(b)
    Hq = Hte[:1536]
    exact = np.asarray(exact_topk(Wd, bd, jnp.asarray(Hq), k))

    # tight budgets — the discriminating regime (precision < 1)
    for budget in (20, 60):
        l2s_cfg = L2SConfig(num_clusters=100, budget=budget, outer_iters=3,
                            sgd_steps=250)
        for name, state in (
            ("L2S", fit_l2s(Htr, ytr, cfg.vocab_size, l2s_cfg)),
            ("kmeans-only", kmeans_only_screen(Htr, ytr, cfg.vocab_size,
                                               l2s_cfg)),
        ):
            pq = PerQueryScreen(W, b, state.screen)
            pred = np.stack([pq.topk(Hq[i], k) for i in range(len(Hq))])
            p1 = precision_at_k(pred[:, :1], exact[:, :1])
            p5 = precision_at_k(pred, exact)
            t0 = time.perf_counter()
            for i in range(400):
                pq.topk(Hq[i], k)
            us = (time.perf_counter() - t0) / 400 * 1e6
            lbar = avg_candidate_size(state.screen, Hte)
            csv_row(f"table4/{name}-B{budget}", us,
                    f"p1={p1:.3f},p5={p5:.3f},lbar={lbar:.0f}")


if __name__ == "__main__":
    run()
