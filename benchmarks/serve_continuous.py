"""Continuous-batching heavy-traffic harness: Poisson arrivals, mixed
heads and tiers, admission control — the open-loop load test the paper's
deployment story needs.

Unlike serve_mixed.py (one pre-assembled batch through ``serve_batch``),
this drives ``ContinuousScheduler`` the way live traffic would: request
arrival times are drawn from a Poisson process (exponential inter-arrival
gaps at ``--rate`` requests/s), each request is submitted when the wall
clock reaches its arrival time, and the scheduler ticks continuously —
requests JOIN running decode streams at sequence boundaries, finish at
different times, and over-budget arrivals are rejected or downgraded by a
``BudgetAdmission`` policy wired to the head catalog's
``flops_per_query``.

Reported: sustained tokens/s, reject/downgrade rates, per-head tokens/s,
p50/p95 request latency (submission → last token), max queue depth, and
the recompile count between warmup and the measured run (expected 0 — the
whole point of fixed-width streams over the LRU step cache). A
machine-readable section is merged into ``BENCH_serving.json``.

    PYTHONPATH=src python benchmarks/serve_continuous.py            # full
    PYTHONPATH=src python benchmarks/serve_continuous.py --reduced  # CI

With >1 jax device the standard tier rides "screened-sharded", putting the
mesh-aware stream path under load.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

try:
    from benchmarks.common import update_bench_json
    from benchmarks.serve_mixed import build_engine
except ImportError:                        # script's own dir is sys.path[0]
    from common import update_bench_json
    from serve_mixed import build_engine

from repro.serving import (BudgetAdmission, CircuitBreaker,
                           ContinuousScheduler, FaultInjector, LogicalClock,
                           PagePool, ServeRequest, ServeResult,
                           StreamWatchdog, TierPolicy, Tracer,
                           audit_cost_drift)
from repro.serving.scheduler import TIER_DEADLINES, AdmissionRejected


def _export_trace(tracer, path, label):
    """Write the Chrome trace-event file + a one-line summary; returns the
    JSON-ready trace telemetry for the bench section."""
    if tracer is None:
        return None
    tracer.export_chrome(path)
    evs = tracer.events()
    n_req = sum(1 for e in evs if e["ph"] == "X" and e["name"] == "request")
    print(f"[{label}] trace: {len(evs)} events ({n_req} request spans, "
          f"{tracer.dropped} dropped) -> {path} "
          f"(load in chrome://tracing or ui.perfetto.dev)")
    return {"path": path, "events": len(evs), "request_spans": n_req,
            "dropped": tracer.dropped}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--chaos", action="store_true",
                    help="resilience workload: deterministic FaultInjector "
                         "(transient/permanent/NaN/stall faults) + circuit "
                         "breakers + watchdog on a simulated clock; "
                         "reports the fault funnel, breaker transitions, "
                         "greedy parity of fault-free survivors, and "
                         "recompiles (expected 0 — chaos is host-side)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="paged-KV workload: every prompt = one templated "
                         "system prompt + a short unique suffix, served "
                         "over a shared PagePool with a prefix radix "
                         "cache; reports prefix hit rate, pages in use, "
                         "HBM residency, COW rate, and greedy parity")
    ap.add_argument("--page-size", type=int, default=8,
                    help="[shared-prefix] KV page size in token slots "
                         "(must divide the engine max_len, 80)")
    ap.add_argument("--pool-pages", type=int, default=256,
                    help="[shared-prefix] total pool pages (page 0 is the "
                         "reserved trash page)")
    ap.add_argument("--template-len", type=int, default=48,
                    help="[shared-prefix] shared system-prompt tokens")
    ap.add_argument("--suffix-len", type=int, default=4,
                    help="[shared-prefix] unique per-request suffix tokens")
    ap.add_argument("--parity-checks", type=int, default=4,
                    help="[shared-prefix] completed requests to replay "
                         "through solo engine.generate for bit-identity")
    ap.add_argument("--requests", type=int, default=None,
                    help="total arrivals (default 16 reduced / 64)")
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate, requests/s "
                         "(default 200 reduced / 50)")
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--budget-x", type=float, default=3.0,
                    help="flops budget as a multiple of the priciest "
                         "candidate head's flops_per_query (drives a "
                         "nonzero reject/downgrade rate under burst)")
    ap.add_argument("--deadline-scale", type=float, default=10.0,
                    help="multiply TIER_DEADLINES by this (default 10: "
                         "CPU/interpret decode is orders slower than the "
                         "TPU the sub-second tiers assume; set 1.0 to "
                         "measure preemption churn)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export the measured run's span timeline as a "
                         "Chrome trace-event JSON file (chrome://tracing / "
                         "Perfetto); works with the standard, --chaos and "
                         "--shared-prefix workloads")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable output file ('' disables)")
    args = ap.parse_args(argv)
    n_req = args.requests or (16 if args.reduced else 64)
    rate = args.rate or (200.0 if args.reduced else 50.0)
    max_new = args.max_new or (8 if args.reduced else 32)

    cfg, corpus, engine = build_engine(args.reduced, args.seed)

    if args.chaos:
        return _chaos(args, cfg, corpus, engine,
                      args.requests or (24 if args.reduced else 64))
    if args.shared_prefix:
        return _shared_prefix(args, cfg, corpus, engine, n_req, rate)

    standard = "screened-sharded" if jax.device_count() > 1 else "svd"
    policy = TierPolicy({"realtime": "screened", "standard": standard,
                         "batch": "exact"}, default="screened")
    tiers = ["realtime", "standard", "batch"]
    prompts = corpus.sample_batch(n_req, 16, seed=42)
    requests = []
    for i, p in enumerate(prompts):
        sampled = (i % 6 == 5)
        requests.append(ServeRequest(
            prompt=p, max_new=max_new, latency_tier=tiers[i % 3],
            temperature=0.8 if sampled else None,
            top_p=0.95 if sampled else 1.0, seed=7))

    # flops budget off the same catalog admission reads; generous enough
    # that steady-state traffic flows, tight enough that a Poisson burst
    # sheds load (the reject path must be exercised, not just compiled)
    catalog = engine.head_catalog(tuple(policy.candidates))
    top_flops = max(m["flops_per_query"] for m in catalog.values())
    budget = args.budget_x * top_flops

    # warmup: compile every (candidate head × greedy/sample) stream combo
    # the measured run could touch. Routing alone does not bound this —
    # admission may DOWNGRADE any request (greedy or sampled) onto any
    # cheaper cataloged head, so the warmup pins each combo explicitly via
    # the request.head escape hatch instead of trusting the policy's map.
    warm_p = corpus.sample_batch(2, 16, seed=7)
    warmup = []
    for name in catalog:
        warmup.append(ServeRequest(prompt=warm_p[0], max_new=2, head=name))
        warmup.append(ServeRequest(prompt=warm_p[1], max_new=2, head=name,
                                   temperature=0.8, top_p=0.95, seed=7))
    ContinuousScheduler(engine, policy=policy, max_slots=args.max_slots,
                        max_streams=2 * len(catalog)).serve(warmup)
    counts0 = engine.compiled_step_counts()

    deadlines = {t: s * args.deadline_scale for t, s in TIER_DEADLINES.items()}
    # tracer on the SAME clock the scheduler reads, so request spans and
    # deadline bookkeeping share one timeline
    tracer = Tracer(clock=time.monotonic) if args.trace else None
    sched = ContinuousScheduler(
        engine, policy=policy,
        admission=BudgetAdmission(flops_budget=budget),
        max_slots=args.max_slots, max_streams=8, deadlines=deadlines,
        tracer=tracer)
    wall = _drive(sched, requests, rate, args.seed)
    counts1 = engine.compiled_step_counts()
    recompiles = sum(counts1.values()) - sum(counts0.values())
    trace_info = _export_trace(tracer, args.trace, "serve_continuous")

    stats = sched.stats
    snap = stats.snapshot()
    results = sched.results()
    completed_tokens = sum(len(r.tokens) for r in results
                           if isinstance(r, ServeResult))
    print(f"\n[serve_continuous] vocab={cfg.vocab_size} arrivals={n_req} "
          f"rate={rate:.0f}/s max_new={max_new} "
          f"devices={jax.device_count()} flops_budget={budget:.3g}")
    print(f"[serve_continuous] {completed_tokens} tokens in {wall:.2f}s = "
          f"{completed_tokens / wall:.0f} tok/s sustained | admitted "
          f"{stats.admitted}/{stats.submitted} (rejected {stats.rejected}, "
          f"downgraded {stats.downgraded}, preempted {stats.preempted})")
    print(f"[serve_continuous] latency p50 {snap['latency']['p50_s']:.3f}s "
          f"p95 {snap['latency']['p95_s']:.3f}s | max queue depth "
          f"{stats.max_queue_depth} | recompiles after warmup {recompiles} "
          f"(expected 0)")
    print(f"{'head':<18}{'requests':>9}{'tokens':>8}{'tok/s':>10}")
    for head, d in snap["per_head"].items():
        print(f"{head:<18}{d['requests']:>9}{d['tokens']:>8}"
              f"{d['tokens_per_s']:>10.0f}")
    # cost-model drift audit: cataloged flops/bytes per query vs the HLO-
    # measured executables and wall-clock timing, per active head — the
    # numbers CostAwarePolicy / BudgetAdmission priced this run with
    drift = audit_cost_drift(engine, tuple(policy.candidates))
    print(f"{'head':<18}{'pred flops':>12}{'hlo flops':>12}{'ratio':>7}")
    for head, d in drift.items():
        if "error" in d:
            print(f"{head:<18}  audit error: {d['error']}")
            continue
        pf = d["predicted"]["flops_per_query"]
        mf = d["measured"].get("hlo_flops")
        rf = d["ratio"]["flops"]
        print(f"{head:<18}{pf:>12.3g}"
              f"{mf if mf is not None else float('nan'):>12.3g}"
              f"{rf if rf is not None else float('nan'):>7.2f}")
    if args.json:
        path = update_bench_json("serve_continuous", {
            "devices": jax.device_count(), "vocab": cfg.vocab_size,
            "arrivals": n_req, "rate": rate, "max_new": max_new,
            "reduced": args.reduced, "flops_budget": budget,
            "wall_s": wall, "completed_tokens": completed_tokens,
            "tokens_per_s": completed_tokens / wall,
            "recompiles": recompiles, "trace": trace_info, **snap,
        }, path=args.json)
        update_bench_json("cost_drift", {
            "devices": jax.device_count(), "vocab": cfg.vocab_size,
            "reduced": args.reduced, "per_head": drift,
        }, path=args.json)
        print(f"[serve_continuous] wrote {path}")
    return 0


def _drive(sched, requests, rate, seed):
    """Open-loop Poisson arrivals at ``rate`` req/s; returns wall seconds."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(requests)))
    t0 = time.perf_counter()
    nxt = 0
    while nxt < len(requests) or sched.busy:
        now = time.perf_counter() - t0
        while nxt < len(requests) and arrivals[nxt] <= now:
            sched.submit(requests[nxt])
            nxt += 1
        if sched.busy:
            sched.step()
        elif nxt < len(requests):         # idle until the next arrival
            time.sleep(max(0.0, arrivals[nxt] - (time.perf_counter() - t0)))
    return time.perf_counter() - t0


def _chaos(args, cfg, corpus, engine, n_req):
    """--chaos: the resilience layer under deterministic fire.

    All-greedy traffic over three heads on a simulated ``LogicalClock``
    shared by scheduler, breaker and injector (the whole run replays
    bit-identically from the seed). The armed fault schedule exercises
    every degradation path: transient step faults (bounded retry),
    a permanent fault (hard breaker trip → fallback re-route → cooldown →
    half-open probe → close), NaN output corruption (guard detection),
    injected stalls (watchdog eviction), and per-request timeouts.

    Invariants printed and serialized: ZERO unhandled exceptions, the
    funnel closes (arrivals == completed + typed rejects), fault-free
    survivors decode bit-identical to solo ``engine.generate``, and the
    recompile count after warmup is 0 — fault injection and detection are
    entirely host-side, so chaos runs compile exactly what healthy runs
    compile."""
    max_new = args.max_new or 8
    policy = TierPolicy({"realtime": "screened", "standard": "svd",
                         "batch": "exact"}, default="screened")
    catalog = engine.head_catalog(tuple(policy.candidates))
    tiers = ["realtime", "standard", "batch"]
    prompts = corpus.sample_batch(n_req, 16, seed=42)
    requests = []
    for i, p in enumerate(prompts):
        # two late timeouts for coverage; everything else unbounded
        requests.append(ServeRequest(
            prompt=p, max_new=max_new, latency_tier=tiers[i % 3],
            timeout_s=0.004 if i in (5, 11) else None))

    # warmup compiles every greedy stream the run (or a fallback) could
    # touch; chaos itself is host-side and adds zero executables
    warm_p = corpus.sample_batch(1, 16, seed=7)
    warmup = [ServeRequest(prompt=warm_p[0], max_new=2, head=name)
              for name in catalog]
    ContinuousScheduler(engine, policy=policy, max_slots=args.max_slots,
                        max_streams=len(catalog) + 1).serve(warmup)
    counts0 = engine.compiled_step_counts()

    clock = LogicalClock(0.0, dt_per_read=1e-3)
    injector = FaultInjector(seed=args.seed, clock=clock)
    injector.arm("step", "transient", head="screened", count=3, after=2)
    injector.arm("step", "permanent", head="svd", count=1, after=4)
    injector.arm("step", "nan", head="screened", count=2, after=12)
    injector.arm("step", "stall", head="exact", count=8, after=3)
    injector.arm("join", "transient", head="svd", count=1, after=8)
    injector.arm("tick", "delay", delay_s=2e-3, rate=0.1, count=5)
    breaker = CircuitBreaker(failure_threshold=3, cooldown_s=0.05,
                             clock=clock)
    watchdog = StreamWatchdog(stall_timeout_s=5e-3)
    deadlines = {t: s * args.deadline_scale
                 for t, s in TIER_DEADLINES.items()}
    # PEEK the logical clock (clock.t, not clock()): reads auto-advance
    # the shared simulated timeline, so a tracing read would perturb the
    # deterministic fault/deadline schedule the run replays
    tracer = Tracer(clock=lambda: clock.t) if args.trace else None
    sched = ContinuousScheduler(
        engine, policy=policy, max_slots=args.max_slots, max_streams=8,
        deadlines=deadlines, clock=clock, fault_injector=injector,
        breaker=breaker, watchdog=watchdog, max_retries=2, tracer=tracer)
    t0 = time.perf_counter()
    unhandled = None
    try:
        for r in requests:
            sched.submit(r)
        results = sched.drain(max_ticks=5000)
    except Exception as e:                     # noqa: BLE001 — the headline
        unhandled = f"{type(e).__name__}: {e}"
        results = sched.results()
    wall = time.perf_counter() - t0
    counts1 = engine.compiled_step_counts()
    recompiles = sum(counts1.values()) - sum(counts0.values())
    trace_info = _export_trace(tracer, args.trace, "serve_chaos")

    completed = [(i, r) for i, r in enumerate(results)
                 if isinstance(r, ServeResult)]
    rejects = [r for r in results if isinstance(r, AdmissionRejected)]
    funnel_closed = len(completed) + len(rejects) == n_req
    clean = [(i, r) for i, r in completed if i not in sched.fault_rids]
    parity = True
    for i, r in clean[:8]:
        ref = engine.generate(requests[i].prompt[None],
                              requests[i].max_new).tokens[0]
        parity = parity and bool(np.array_equal(r.tokens, ref))

    snap = sched.stats.snapshot()
    rz = snap["resilience"] or {}
    print(f"\n[serve_chaos] arrivals={n_req} max_new={max_new} heads="
          f"{list(catalog)} devices={jax.device_count()} wall={wall:.2f}s")
    print(f"[serve_chaos] unhandled exceptions: "
          f"{unhandled or 0} (expected 0)")
    print(f"[serve_chaos] funnel: {len(completed)} completed + "
          f"{len(rejects)} typed rejects == {n_req} arrivals: "
          f"{funnel_closed}")
    print(f"[serve_chaos] faults {injector.telemetry()['fired_total']} "
          f"fired ({rz.get('faults_transient', 0)} transient, "
          f"{rz.get('faults_permanent', 0)} permanent) | retries "
          f"{rz.get('retries', 0)} fallbacks {rz.get('fallbacks', 0)} "
          f"faulted {rz.get('faulted', 0)} timed_out "
          f"{rz.get('timed_out', 0)} stalls "
          f"{rz.get('watchdog_stalls', 0)}")
    print(f"[serve_chaos] breakers: trips {rz.get('breaker_trips', 0)} "
          f"half-opens {rz.get('breaker_half_opens', 0)} closes "
          f"{rz.get('breaker_closes', 0)} | states "
          f"{rz.get('breaker_states', {})}")
    print(f"[serve_chaos] greedy parity of {len(clean[:8])} fault-free "
          f"survivors: {parity} | recompiles after warmup {recompiles} "
          f"(expected 0)")
    ok = unhandled is None and funnel_closed and parity and recompiles == 0
    if args.json:
        path = update_bench_json("serve_chaos", {
            "devices": jax.device_count(), "vocab": cfg.vocab_size,
            "arrivals": n_req, "max_new": max_new,
            "reduced": args.reduced, "wall_s": wall,
            "unhandled": unhandled, "funnel_closed": funnel_closed,
            "completed": len(completed), "typed_rejects": len(rejects),
            "fault_rids": len(sched.fault_rids),
            "faults_fired": injector.telemetry(),
            "greedy_parity": parity, "parity_checked": len(clean[:8]),
            "recompiles": recompiles, "ok": ok, "trace": trace_info, **snap,
        }, path=args.json)
        print(f"[serve_chaos] wrote {path}")
    return 0 if ok else 1


def _shared_prefix(args, cfg, corpus, engine, n_req, rate):
    """--shared-prefix: templated prompts over one shared ``PagePool``.

    Every request is the SAME template prompt (a long "system prompt")
    plus a short unique suffix — the agent-serving shape paged KV exists
    for. A warmup scheduler shares the pool, so it both compiles every
    stream step the measured run touches AND primes the radix cache with
    the template's pages; the measured window then sees a per-request
    prefix hit of template/(template+suffix) tokens, zero step recompiles,
    and greedy tokens bit-identical to solo ``engine.generate``."""
    max_new = args.max_new or 8
    Tp = args.template_len + args.suffix_len
    if Tp + max_new > engine.max_len:
        raise SystemExit(f"template+suffix+max_new = {Tp + max_new} exceeds "
                         f"engine max_len {engine.max_len}")
    template = corpus.sample_batch(1, args.template_len, seed=5)[0]
    suffixes = corpus.sample_batch(n_req + 2, args.suffix_len, seed=43)
    tiers = ["realtime", "standard", "batch"]
    requests = [ServeRequest(
        prompt=np.concatenate([template, suffixes[i]]).astype(np.int32),
        max_new=max_new, latency_tier=tiers[i % 3]) for i in range(n_req)]

    standard = "screened-sharded" if jax.device_count() > 1 else "svd"
    policy = TierPolicy({"realtime": "screened", "standard": standard,
                         "batch": "exact"}, default="screened")
    catalog = engine.head_catalog(tuple(policy.candidates))
    pool = PagePool(num_pages=args.pool_pages, page_size=args.page_size)

    # warmup shares the POOL: compiles per-head streams + chunked resume
    # prefill for the template grid AND pins the template's pages in the
    # radix cache, so the measured window starts hot on both axes
    warmup = [ServeRequest(
        prompt=np.concatenate([template, suffixes[n_req + i % 2]])
        .astype(np.int32), max_new=2, head=name)
        for i, name in enumerate(catalog)]
    ContinuousScheduler(engine, policy=policy, max_slots=args.max_slots,
                        max_streams=len(catalog) + 1,
                        kv_pool=pool).serve(warmup)
    counts0 = engine.compiled_step_counts()
    radix = pool.radix
    hit0, tot0 = radix.tokens_hit, radix.tokens_total

    deadlines = {t: s * args.deadline_scale
                 for t, s in TIER_DEADLINES.items()}
    tracer = Tracer(clock=time.monotonic) if args.trace else None
    sched = ContinuousScheduler(engine, policy=policy,
                                max_slots=args.max_slots, max_streams=8,
                                deadlines=deadlines, kv_pool=pool,
                                tracer=tracer)
    wall = _drive(sched, requests, rate, args.seed)
    counts1 = engine.compiled_step_counts()
    recompiles = sum(counts1.values()) - sum(counts0.values())
    trace_info = _export_trace(tracer, args.trace, "serve_shared_prefix")
    hit_rate = (radix.tokens_hit - hit0) / max(1, radix.tokens_total - tot0)

    results = sched.results()
    served = [(req, r) for req, r in zip(requests, results)
              if isinstance(r, ServeResult)]
    parity = True
    for req, r in served[:args.parity_checks]:
        ref = engine.generate(req.prompt[None], req.max_new).tokens[0]
        parity = parity and bool(np.array_equal(r.tokens, ref))

    snap = sched.stats.snapshot()
    ptel = snap["pool"]
    tokens = sum(len(r.tokens) for _, r in served)
    print(f"\n[serve_shared_prefix] arrivals={n_req} template="
          f"{args.template_len} suffix={args.suffix_len} page={args.page_size} "
          f"pool={args.pool_pages} devices={jax.device_count()}")
    print(f"[serve_shared_prefix] {tokens} tokens in {wall:.2f}s = "
          f"{tokens / wall:.0f} tok/s | completed {len(served)}/{n_req} "
          f"(preempted {sched.stats.preempted})")
    print(f"[serve_shared_prefix] prefix hit rate {hit_rate:.3f} (measured "
          f"window; cumulative {radix.hit_rate:.3f}) | pages in use "
          f"{ptel['pages_in_use']}/{ptel['pages_total']} (peak "
          f"{ptel['peak_pages_in_use']}) | cow {ptel['cow_copies']} | "
          f"hbm resident {ptel['hbm_resident_bytes']} B")
    print(f"[serve_shared_prefix] greedy parity {parity} | recompiles after "
          f"warmup {recompiles} (expected 0)")
    if args.json:
        path = update_bench_json("serve_shared_prefix", {
            "devices": jax.device_count(), "vocab": cfg.vocab_size,
            "arrivals": n_req, "rate": rate, "max_new": max_new,
            "reduced": args.reduced, "template_len": args.template_len,
            "suffix_len": args.suffix_len, "page_size": args.page_size,
            "pool_pages": args.pool_pages,
            "wall_s": wall, "completed_tokens": tokens,
            "tokens_per_s": tokens / wall,
            "prefix_hit_rate": hit_rate,
            "greedy_parity": parity, "recompiles": recompiles,
            "trace": trace_info, **snap,
        }, path=args.json)
        print(f"[serve_shared_prefix] wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
