"""Paper Table 1 / Figs 2-4: precision@1/@5 vs speedup of L2S against every
competing method — every row is a registered ``SoftmaxHead``, enumerated
from ``repro.heads`` over one shared (W, b, screen) context instead of
hand-calling five baseline classes.

Timing protocol = the paper's: ONE query at a time on a single CPU thread
(numpy-backed heads throughout, so per-op overheads are identical; the L2S
rows use the "screened-cpu" per-query adapter). Precision is evaluated over
a 2048-query held-out set against the exact softmax top-k. Each row also
reports the head's analytic cost model (``flops_per_query``).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (csv_row, get_artifacts, head_context,
                               time_head_per_query)
from repro import heads
from repro.configs import L2SConfig
from repro.core import fit_l2s, precision_at_k
from repro.core.evaluate import (avg_candidate_size, exact_topk,
                                 full_softmax_topk_numpy)
from repro.core.train_l2s import kmeans_only_screen

N_EVAL = 2048
N_TIME = 400


def run(k: int = 5):
    cfg, model, params, W, b, Htr, ytr, Hte, yte, _ = get_artifacts()
    Hq = Hte[:N_EVAL]
    exact = np.asarray(exact_topk(jnp.asarray(W), jnp.asarray(b),
                                  jnp.asarray(Hq), k))

    t0 = time.perf_counter()
    for i in range(N_TIME):
        full_softmax_topk_numpy(W, b, Hq[i], k)
    t_full = (time.perf_counter() - t0) / N_TIME
    csv_row("table1/full-softmax", t_full * 1e6,
            "speedup=1.00x,p1=1.000,p5=1.000")

    def report(label, head, extra=""):
        pred = np.stack([np.asarray(head.topk(Hq[i:i + 1], k)[0][0])
                         for i in range(N_EVAL)])
        p1 = precision_at_k(pred[:, :1], exact[:, :1])
        p5 = precision_at_k(pred, exact)
        t = time_head_per_query(head, Hq, k, n_time=N_TIME)
        csv_row(f"table1/{label}", t * 1e6,
                f"speedup={t_full / t:.2f}x,p1={p1:.3f},p5={p5:.3f},"
                f"flops={head.flops_per_query:.0f}{extra}")

    # --- L2S (the paper) at two budgets (time/accuracy tradeoff) ---
    for budget in (100, 300):
        t0 = time.perf_counter()
        state = fit_l2s(Htr, ytr, cfg.vocab_size,
                        L2SConfig(num_clusters=100, budget=budget,
                                  outer_iters=3, sgd_steps=250))
        fit_s = time.perf_counter() - t0
        lbar = avg_candidate_size(state.screen, Hte)
        head = heads.get("screened-cpu",
                         **head_context(W, b, screen=state.screen))
        report(f"L2S-B{budget}", head,
               extra=f",lbar={lbar:.0f},fit_s={fit_s:.0f}")

    # --- spherical k-means ablation (Table 4 row) ---
    km = kmeans_only_screen(Htr, ytr, cfg.vocab_size,
                            L2SConfig(num_clusters=100, budget=100))
    report("kmeans-screen",
           heads.get("screened-cpu", **head_context(W, b, screen=km.screen)))

    # --- §4.1 competitors: enumerate the head registry ---
    freq = np.bincount(ytr[:, 0], minlength=cfg.vocab_size)
    competitor_rows = [
        ("svd-softmax-r16", "svd", dict(rho=16, n_top=400)),
        ("svd-softmax-r32", "svd", dict(rho=32, n_top=800)),
        ("adaptive-softmax", "shortlist",
         dict(freq_order=np.argsort(-freq), n_head=800, n_tails=4)),
        ("greedy-mips", "greedy-mips", dict(budget=512)),
        ("lsh-mips", "lsh-mips", dict(bands=8, bits=10)),
        ("pca-mips", "pca-mips", dict(depth=5)),
    ]
    registered = set(heads.names())
    for label, name, kw in competitor_rows:
        assert name in registered, f"{name} missing from head registry"
        report(label, heads.get(name, **head_context(W, b, **kw)))

    # --- adaptive frequency-tiered head (trained-unigram tiers; the flops
    #     column is the TIER-WEIGHTED expected cost — short-list + gates +
    #     p_descend × expected tail width, see benchmarks/README.md). The
    #     time column is jax per-query dispatch, not the numpy protocol:
    #     compare it to the other rows via flops, not speedup. ---
    ad = heads.get("adaptive", **head_context(W, b, counts=freq,
                                              shortlist=800, n_tails=4))
    report("adaptive-tiered", ad,
           extra=f",p_descend={ad._lay.p_descend:.3f}")

    # --- vocab-sharded heads (multi-device only; flops are PER SHARD —
    #     see benchmarks/README.md for how to read them) ---
    if jax.device_count() > 1:
        for name in ("exact-sharded", "screened-sharded"):
            head = heads.get(name, **head_context(W, b, screen=state.screen))
            csv_row(f"table1/{name}", float("nan"),
                    f"shards={head.n_shards},"
                    f"flops_per_shard={head.flops_per_query:.0f}")
        ads = heads.get("adaptive-sharded",
                        **head_context(W, b, counts=freq, shortlist=800,
                                       n_tails=4))
        csv_row("table1/adaptive-sharded", float("nan"),
                f"shards={ads.n_shards},"
                f"flops_per_shard={ads.flops_per_query:.0f},"
                f"p_descend={ads._lay.p_descend:.3f}")


if __name__ == "__main__":
    run()
