"""Paper Table 1 / Figs 2-4: precision@1/@5 vs speedup of L2S against every
competing method.

Timing protocol = the paper's: ONE query at a time on a single CPU thread,
ragged candidate sets (no batch padding), numpy for every method so per-op
overheads are identical. Precision is evaluated over a 2048-query held-out
set against the exact softmax top-k.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_artifacts
from repro.configs import L2SConfig
from repro.core import fit_l2s, precision_at_k
from repro.core.baselines import (AdaptiveShortlist, GreedyMIPS, LSHMIPS,
                                  PCAMIPS, SVDSoftmax)
from repro.core.evaluate import (PerQueryScreen, avg_candidate_size,
                                 exact_topk, full_softmax_topk_numpy)
from repro.core.train_l2s import kmeans_only_screen

N_EVAL = 2048
N_TIME = 400


def _time_per_query(fn, H, k) -> float:
    t0 = time.perf_counter()
    for i in range(N_TIME):
        fn(H[i], k)
    return (time.perf_counter() - t0) / N_TIME


def run(k: int = 5):
    cfg, model, params, W, b, Htr, ytr, Hte, yte, _ = get_artifacts()
    Wd, bd = jnp.asarray(W), jnp.asarray(b)
    Hq = Hte[:N_EVAL]
    exact = np.asarray(exact_topk(Wd, bd, jnp.asarray(Hq), k))

    t_full = _time_per_query(lambda h, kk: full_softmax_topk_numpy(W, b, h, kk),
                             Hq, k)
    csv_row("table1/full-softmax", t_full * 1e6,
            "speedup=1.00x,p1=1.000,p5=1.000")

    def report(name, topk_fn, extra=""):
        pred = np.stack([topk_fn(Hq[i], k) for i in range(N_EVAL)])
        p1 = precision_at_k(pred[:, :1], exact[:, :1])
        p5 = precision_at_k(pred, exact)
        t = _time_per_query(topk_fn, Hq, k)
        csv_row(f"table1/{name}", t * 1e6,
                f"speedup={t_full / t:.2f}x,p1={p1:.3f},p5={p5:.3f}{extra}")

    # --- L2S (the paper) at two budgets (time/accuracy tradeoff) ---
    for budget in (100, 300):
        t0 = time.perf_counter()
        state = fit_l2s(Htr, ytr, cfg.vocab_size,
                        L2SConfig(num_clusters=100, budget=budget,
                                  outer_iters=3, sgd_steps=250))
        fit_s = time.perf_counter() - t0
        lbar = avg_candidate_size(state.screen, Hte)
        pq = PerQueryScreen(W, b, state.screen)
        report(f"L2S-B{budget}", pq.topk,
               extra=f",lbar={lbar:.0f},fit_s={fit_s:.0f}")

    # --- spherical k-means ablation (Table 4 row) ---
    km = kmeans_only_screen(Htr, ytr, cfg.vocab_size,
                            L2SConfig(num_clusters=100, budget=100))
    report("kmeans-screen", PerQueryScreen(W, b, km.screen).topk)

    # --- SVD-softmax (Shim et al.) ---
    for rho, n_top in ((16, 400), (32, 800)):
        svd = SVDSoftmax.build(W, b, rho=rho, n_top=n_top)
        report(f"svd-softmax-r{rho}",
               lambda h, kk, s=svd: s.topk(h[None], kk)[0])

    # --- Adaptive-softmax-style shortlist (Grave et al.) ---
    freq = np.bincount(ytr[:, 0], minlength=cfg.vocab_size)
    ada = AdaptiveShortlist.build(W, b, np.argsort(-freq), n_head=800,
                                  n_tails=4)
    report("adaptive-softmax", lambda h, kk: ada.topk(h[None], kk)[0])

    # --- Greedy-MIPS (Yu et al.) ---
    gm = GreedyMIPS.build(W, b, budget=512)
    report("greedy-mips", lambda h, kk: gm.topk(h[None], kk)[0])

    # --- LSH-MIPS ---
    lsh = LSHMIPS.build(W, b, bands=8, bits=10)
    report("lsh-mips", lambda h, kk: lsh.topk(h[None], kk)[0])

    # --- PCA-MIPS ---
    pca = PCAMIPS.build(W, b, depth=5)
    report("pca-mips", lambda h, kk: pca.topk(h[None], kk)[0])


if __name__ == "__main__":
    run()
