"""Paper Table 5 / §7.3: full-distribution perplexity with the low-rank
fallback for out-of-candidate logits (Shim et al. style), vs exact softmax
and vs pure SVD-softmax at the same rank."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_artifacts
from repro.configs import L2SConfig
from repro.core import fit_l2s
from repro.core.lowrank import (build_lowrank, exact_perplexity, perplexity)

RANK = 32          # paper uses 20 for PTB-Small; 32 here — the
                   # synthetic corpus has fatter tails (see notes)


def run():
    cfg, model, params, W, b, Htr, ytr, Hte, yte, targets = get_artifacts()
    Hppl, tgt = targets
    Hppl, tgt = Hppl[:4096], tgt[:4096]

    t0 = time.perf_counter()
    ppl_exact = exact_perplexity(W, b, Hppl, tgt)
    t_exact = time.perf_counter() - t0
    csv_row("table5/exact", t_exact / len(Hppl) * 1e6,
            f"ppl={ppl_exact:.2f},speedup=1.00x")

    U, Vt = build_lowrank(W, RANK)

    state = fit_l2s(Htr, ytr, cfg.vocab_size,
                    L2SConfig(num_clusters=100, budget=400, outer_iters=2,
                              sgd_steps=200))
    t0 = time.perf_counter()
    ppl_l2s = perplexity(W, b, U, Vt, state.screen, Hppl, tgt)
    t_l2s = time.perf_counter() - t0
    # analytic softmax-cost speedup: (r + L̄ + rank·fallback) vs L, d-dim ops
    csv_row("table5/L2S+lowrank", t_l2s / len(Hppl) * 1e6,
            f"ppl={ppl_l2s:.2f},ppl_delta={(ppl_l2s-ppl_exact)/ppl_exact*100:.2f}%")

    # pure low-rank (SVD-softmax style preview used for ALL logits)
    t0 = time.perf_counter()
    ppl_svd = perplexity(W, b, U, Vt,
                         _empty_screen(state.screen), Hppl, tgt)
    t_svd = time.perf_counter() - t0
    csv_row("table5/svd-only", t_svd / len(Hppl) * 1e6,
            f"ppl={ppl_svd:.2f},ppl_delta={(ppl_svd-ppl_exact)/ppl_exact*100:.2f}%")


def _empty_screen(screen):
    """Screen with empty candidate sets → every logit is low-rank."""
    import dataclasses
    return dataclasses.replace(
        screen,
        cand_idx=jnp.full_like(screen.cand_idx, screen.vocab_size),
        cand_len=jnp.zeros_like(screen.cand_len))


if __name__ == "__main__":
    run()
