"""Fused vs unfused L2S kernel-path microbenchmark.

Compares ``screened_fused_topk_tpu`` (in-VMEM subset softmax + top-k, only
(B, k) results reach HBM) against ``screened_topk_tpu`` (candidate-logit
tile written back, XLA-side masking + top-k) on synthetic packed heads:

  * wall time per call (median of timed reps, post-warmup)
  * XLA bytes-accessed from HLO cost analysis, plus a structural check that
    the fused executable contains NO (B, K·V_BLK) f32 buffer

Interpret-mode runnable (the default here — this container is CPU-only, so
wall times measure the EMULATED kernels and only the bytes/buffer columns
reflect the TPU story; pass --no-interpret on real TPUs for honest timing).

    PYTHONPATH=src python benchmarks/kernel_fused.py              # full
    PYTHONPATH=src python benchmarks/kernel_fused.py --reduced    # CI smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import (pack_head_blocks, screened_fused_topk_tpu,
                               screened_topk_tpu)
from repro.launch.hlo_cost import materializes_f32_buffer, xla_bytes_accessed

try:
    from benchmarks.common import csv_row
except ModuleNotFoundError:    # run as `python benchmarks/kernel_fused.py`:
    from common import csv_row  # the script's own dir is sys.path[0]


def _has_candidate_tile(hlo: str, B: int, K: int) -> bool:
    return materializes_f32_buffer(hlo, B, K, 128)


def _time(fn, *args, reps: int, **kw) -> float:
    jax.block_until_ready(fn(*args, **kw))          # compile + warm
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6            # µs


def run(reduced: bool = False, interpret: bool = True):
    if reduced:
        cases = [(16, 8, 128, 5, 1500)]             # (B, K, d, k, L)
        reps = 3
    else:
        cases = [(32, 16, 512, 5, 4000),
                 (32, 16, 512, 64, 4000),
                 (8, 8, 256, 5, 2000)]
        reps = 10
    rng = np.random.default_rng(0)
    print(f"{'B':>4} {'K':>3} {'d':>4} {'k':>3} | {'unfused µs':>11} "
          f"{'fused µs':>11} | {'unfused MB':>10} {'fused MB':>9} "
          f"{'tile?':>11}")
    for B, K, d, k, L in cases:
        W = jnp.asarray(rng.standard_normal((L, d)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((L,)), jnp.float32)
        Wb, bb = pack_head_blocks(W, b)
        r = 8
        v = jnp.asarray(rng.standard_normal((r, d)), jnp.float32)
        cand = jnp.asarray(rng.integers(0, Wb.shape[0] + 1, (r, K)),
                           jnp.int32)
        h = jnp.asarray(rng.standard_normal((B, d)), jnp.float32)
        args = (Wb, bb, v, cand, h)
        kw = dict(k=k, interpret=interpret)

        iu, vu = screened_topk_tpu(*args, **kw)
        if_, vf, _ = screened_fused_topk_tpu(*args, **kw)
        assert np.array_equal(np.asarray(iu), np.asarray(if_)), \
            "fused/unfused id mismatch"
        assert np.array_equal(np.asarray(vu), np.asarray(vf)), \
            "fused/unfused val mismatch"

        t_u = _time(screened_topk_tpu, *args, reps=reps, **kw)
        t_f = _time(screened_fused_topk_tpu, *args, reps=reps, **kw)
        cu = screened_topk_tpu.lower(*args, **kw).compile()
        cf = screened_fused_topk_tpu.lower(*args, **kw).compile()
        b_u, b_f = xla_bytes_accessed(cu), xla_bytes_accessed(cf)
        tiles = (f"{'Y' if _has_candidate_tile(cu.as_text(), B, K) else 'N'}"
                 f"/{'Y' if _has_candidate_tile(cf.as_text(), B, K) else 'N'}")
        assert not _has_candidate_tile(cf.as_text(), B, K), \
            "fused executable materialized the candidate-logit tile"
        assert b_f < b_u, "fused path should access strictly fewer bytes"
        print(f"{B:>4} {K:>3} {d:>4} {k:>3} | {t_u:>11.1f} {t_f:>11.1f} | "
              f"{b_u / 1e6:>10.2f} {b_f / 1e6:>9.2f} {tiles:>11}")
        csv_row(f"kernel_fused/B{B}_K{K}_d{d}_k{k}", t_f,
                f"unfused_us={t_u:.1f},bytes_fused={b_f:.0f},"
                f"bytes_unfused={b_u:.0f}")
    print("\n(tile? = unfused/fused executables containing the "
          "(B, K·V_BLK) f32 candidate-logit buffer — the fused column "
          "must be N)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true",
                    help="one small case, few reps (CI smoke)")
    ap.add_argument("--no-interpret", action="store_true",
                    help="compile the Pallas kernels for the real backend")
    a = ap.parse_args()
    run(reduced=a.reduced, interpret=not a.no_interpret)
