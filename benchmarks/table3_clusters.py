"""Paper Table 3: L2S robustness to the number of clusters r. The budget B is
co-varied (paper protocol: keep total prediction cost ~constant)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_artifacts, time_fn
from repro.configs import L2SConfig
from repro.core import fit_l2s, precision_at_k
from repro.core.evaluate import (PerQueryScreen, avg_candidate_size,
                                 exact_topk)
import time


def run(k: int = 5):
    cfg, model, params, W, b, Htr, ytr, Hte, yte, _ = get_artifacts()
    Wd, bd = jnp.asarray(W), jnp.asarray(b)
    Hq = Hte[:1536]
    exact = np.asarray(exact_topk(Wd, bd, jnp.asarray(Hq), k))

    # paper protocol: co-vary (r, B) so r + L̄ stays ~constant
    for r, budget in ((50, 250), (100, 200), (200, 100), (250, 50)):
        state = fit_l2s(Htr, ytr, cfg.vocab_size,
                        L2SConfig(num_clusters=r, budget=budget,
                                  outer_iters=2, sgd_steps=200))
        pq = PerQueryScreen(W, b, state.screen)
        pred = np.stack([pq.topk(Hq[i], k) for i in range(len(Hq))])
        p1 = precision_at_k(pred[:, :1], exact[:, :1])
        p5 = precision_at_k(pred, exact)
        t0 = time.perf_counter()
        for i in range(400):
            pq.topk(Hq[i], k)
        us = (time.perf_counter() - t0) / 400 * 1e6
        lbar = avg_candidate_size(state.screen, Hte)
        csv_row(f"table3/r{r}", us,
                f"budget={budget},p1={p1:.3f},p5={p5:.3f},lbar={lbar:.0f}")


if __name__ == "__main__":
    run()
