"""Heavy-traffic serving harness: N concurrent ServeRequests across >= 3
decode heads through ONE DecodeEngine.serve_batch call.

Reports, per resolved head: request count, tokens served, tokens/s (timed on
a single-head sub-batch after warmup), and the RECOMPILE count the mixed
batch caused (XLA executables added to the engine's cached steps between
warmup and the timed run — the headline number is that it stays 0: routing
mixed traffic reuses each head's one compiled step).

    PYTHONPATH=src python benchmarks/serve_mixed.py              # full
    PYTHONPATH=src python benchmarks/serve_mixed.py --reduced    # CI smoke

The standard tier rides the frequency-tiered "adaptive" head (unigram
counts accumulated during the training loop); with more than one jax
device (e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8) it
upgrades to "adaptive-sharded", exercising the mesh-aware step path with
the rare-tail region vocab-sharded.

Alongside the human-readable table the run merges a machine-readable
section into ``BENCH_serving.json`` (per-head tokens/s, p50/p95 request
latency, recompile counts — see benchmarks/README.md) so the serving perf
trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import update_bench_json
except ImportError:
    from common import update_bench_json  # script's own dir is sys.path[0]

from repro.configs import L2SConfig, TrainConfig, get_config
from repro.core import collect_contexts, fit_l2s
from repro.data import ZipfMarkovCorpus, make_lm_batches
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw_init
from repro.serving import DecodeEngine, ServeRequest, TierPolicy
from repro.utils.timing import LatencyTracker


def build_engine(reduced: bool, seed: int):
    vocab, d, steps = (600, 64, 60) if reduced else (4000, 128, 400)
    cfg = dataclasses.replace(get_config("ptb-small-lstm"), vocab_size=vocab,
                              d_model=d, dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(seed), dtype=jnp.float32)
    corpus = ZipfMarkovCorpus(vocab, branching=64, seed=seed)
    tcfg = TrainConfig(lr=2e-3, total_steps=steps, warmup_steps=10,
                       remat="none", loss_chunk=None)
    step_fn = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    # unigram token counts ride along with training — they parameterize the
    # adaptive head's frequency tiers and its tier-weighted cost model
    counts = np.zeros(vocab, np.int64)
    for batch in make_lm_batches(corpus, steps, 16, 64, seed=1):
        counts += np.bincount(np.asarray(batch["tokens"]).ravel(),
                              minlength=vocab)
        params, opt, _ = step_fn(
            params, opt, {k: jnp.asarray(v) for k, v in batch.items()})
    H, y = collect_contexts(
        model, params,
        [jnp.asarray(b["tokens"])
         for b in make_lm_batches(corpus, 16, 16, 64, seed=9)],
        max_vectors=10_000)
    st = fit_l2s(H, y, vocab,
                 L2SConfig(num_clusters=16 if reduced else 64,
                           budget=48 if reduced else 120,
                           outer_iters=1, sgd_steps=60))
    return cfg, corpus, DecodeEngine(
        model, params, screen=st.screen, max_len=16 + 64,
        head_kwargs=dict(rho=min(16, d), counts=counts,
                         shortlist=max(64, vocab // 8)))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=None,
                    help="total concurrent requests (default 12 reduced / 48)")
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="machine-readable output file ('' disables)")
    args = ap.parse_args(argv)
    n_req = args.requests or (12 if args.reduced else 48)
    max_new = args.max_new or (8 if args.reduced else 32)

    cfg, corpus, engine = build_engine(args.reduced, args.seed)

    # tier → head spread: >= 3 heads always; the standard tier rides the
    # frequency-tiered adaptive head (tail region vocab-sharded whenever a
    # mesh is available), so mixed screened + adaptive traffic shares the
    # engine's cached steps
    standard = "adaptive-sharded" if jax.device_count() > 1 else "adaptive"
    policy = TierPolicy({"realtime": "screened", "standard": standard,
                         "batch": "exact"}, default="screened")
    tiers = ["realtime", "standard", "batch"]
    prompts = corpus.sample_batch(n_req, 16, seed=42)
    requests = []
    for i, p in enumerate(prompts):
        # a slice of sampled traffic rides the same batched steps
        sampled = (i % 6 == 5)
        requests.append(ServeRequest(
            prompt=p, max_new=max_new, latency_tier=tiers[i % 3],
            temperature=0.8 if sampled else None,
            top_p=0.95 if sampled else 1.0))

    engine.serve_batch(requests, policy=policy)          # warmup compiles
    counts0 = engine.compiled_step_counts()
    t0 = time.perf_counter()
    results = engine.serve_batch(requests, policy=policy)
    t_mixed = time.perf_counter() - t0
    counts1 = engine.compiled_step_counts()

    total_tokens = sum(len(r.tokens) for r in results)
    by_head = {}
    for r in results:
        by_head.setdefault(r.head, []).append(r)
    recompiles = {}
    for (head, kind), n in counts1.items():
        d = n - counts0.get((head, kind), 0)
        recompiles[head] = recompiles.get(head, 0) + d

    print(f"\n[serve_mixed] vocab={cfg.vocab_size} requests={n_req} "
          f"max_new={max_new} devices={jax.device_count()}")
    print(f"[serve_mixed] mixed batch: {total_tokens} tokens in "
          f"{t_mixed:.2f}s = {total_tokens / t_mixed:.0f} tok/s, "
          f"{len(by_head)} heads, {engine._cache_size()} cached steps")
    print(f"{'head':<18}{'requests':>9}{'tokens':>8}{'tok/s':>10}"
          f"{'recompiles':>11}")
    per_head_json = {}
    latency = LatencyTracker()
    for head, rs in sorted(by_head.items()):
        # per-head throughput: serve only this head's requests (still warm),
        # pinned via the explicit-head escape hatch
        sub = [dataclasses.replace(r.request, head=head) for r in rs]
        t0 = time.perf_counter()
        engine.serve_batch(sub)
        t_head = time.perf_counter() - t0
        toks = sum(len(r.tokens) for r in rs)
        print(f"{head:<18}{len(rs):>9}{toks:>8}{toks / t_head:>10.0f}"
              f"{recompiles.get(head, 0):>11}")
        per_head_json[head] = {"requests": len(rs), "tokens": toks,
                               "decode_s": t_head,
                               "tokens_per_s": toks / t_head,
                               "recompiles": recompiles.get(head, 0)}
        # batch-mode latency: every request in the sub-batch observes the
        # whole sub-batch's wall time (they finish together)
        for _ in rs:
            latency.record(t_head)
    new_compiles = sum(max(0, v) for v in recompiles.values())
    print(f"[serve_mixed] recompiles caused by the mixed batch: "
          f"{new_compiles} (expected 0)")
    if args.json:
        path = update_bench_json("serve_mixed", {
            "devices": jax.device_count(), "vocab": cfg.vocab_size,
            "requests": n_req, "max_new": max_new, "reduced": args.reduced,
            "total_tokens": total_tokens, "mixed_s": t_mixed,
            "tokens_per_s": total_tokens / t_mixed,
            "recompiles": new_compiles, "latency": latency.snapshot(),
            "per_head": per_head_json,
        }, path=args.json)
        print(f"[serve_mixed] wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
