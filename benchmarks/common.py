"""Shared benchmark substrate: a properly-trained LM on the synthetic
Zipf–Markov corpus (the paper's PTB-Small stand-in — DESIGN §6), cached to
``results/bench_cache`` so the five paper-table benchmarks reuse it.

Scale (CPU-feasible, structure-preserving): vocab 8000, 2-layer LSTM d=128,
2400 train steps. The quantity of interest — precision-vs-speedup orderings of
the screening methods — is scale-robust; see EXPERIMENTS.md for the protocol
argument.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import L2SConfig, TrainConfig, get_config
from repro.core import collect_contexts
from repro.data import ZipfMarkovCorpus, make_lm_batches
from repro.launch.steps import make_train_step
from repro.models import build_model
from repro.optim import adamw_init

CACHE = os.environ.get("BENCH_CACHE", "results/bench_cache")
# BENCH_serving.json section schema: v1 is the historical implicit
# (unversioned) shape; v2 stamps every section with schema_version +
# generated_at. Bump when a section's field contract changes.
SCHEMA_VERSION = 2
VOCAB = 8000
D_MODEL = 128
TRAIN_STEPS = 2400
N_CONTEXTS = 60_000


def bench_config():
    cfg = get_config("ptb-small-lstm")
    return dataclasses.replace(cfg, vocab_size=VOCAB, d_model=D_MODEL,
                               dtype="float32")


def corpus():
    return ZipfMarkovCorpus(VOCAB, branching=96, seed=0)


def get_artifacts():
    """Returns (cfg, model, params, W, b, H_train, y_train, H_test, y_test,
    test_targets). Cached on disk after first build."""
    os.makedirs(CACHE, exist_ok=True)
    pkl = os.path.join(CACHE, "artifacts.pkl")
    cfg = bench_config()
    model = build_model(cfg)
    if os.path.exists(pkl):
        with open(pkl, "rb") as f:
            blob = pickle.load(f)
        params = jax.tree_util.tree_map(jnp.asarray, blob["params"])
        return (cfg, model, params, blob["W"], blob["b"], blob["Htr"],
                blob["ytr"], blob["Hte"], blob["yte"], blob["targets"])

    print("[bench] training benchmark LM "
          f"(vocab={VOCAB}, d={D_MODEL}, steps={TRAIN_STEPS}) ...")
    params = model.init(jax.random.key(0), dtype=jnp.float32)
    tcfg = TrainConfig(lr=3e-3, total_steps=TRAIN_STEPS, warmup_steps=50,
                       remat="none", loss_chunk=None)
    step = jax.jit(make_train_step(model, tcfg))
    opt = adamw_init(params)
    c = corpus()
    t0 = time.time()
    for i, batch in enumerate(make_lm_batches(c, TRAIN_STEPS, 16, 64, seed=1)):
        params, opt, metrics = step(
            params, opt, {k: jnp.asarray(v) for k, v in batch.items()})
        if (i + 1) % 100 == 0:
            print(f"[bench]   step {i+1} loss {float(metrics['loss']):.3f} "
                  f"({time.time()-t0:.0f}s)")
    # harvest contexts + exact top-5 labels
    batches = [jnp.asarray(b["tokens"])
               for b in make_lm_batches(c, 80, 16, 64, seed=99)]
    H, y = collect_contexts(model, params, batches, max_vectors=N_CONTEXTS)
    # held-out targets for perplexity (the NEXT token at each position)
    tgt_batches = [b for b in make_lm_batches(c, 8, 16, 64, seed=555)]
    Hte_list, tgts = [], []
    for b in tgt_batches:
        h, _ = model.forward(params, {"tokens": jnp.asarray(b["tokens"])})
        Hte_list.append(np.asarray(h.reshape(-1, D_MODEL), np.float32))
        tgts.append(np.asarray(b["labels"].reshape(-1), np.int64))
    W, bb = model.softmax_weights(params)
    split = int(0.85 * len(H))
    blob = {
        "params": jax.tree_util.tree_map(np.asarray, params),
        "W": np.asarray(W), "b": np.asarray(bb),
        "Htr": H[:split], "ytr": y[:split],
        "Hte": H[split:], "yte": y[split:],
        "targets": (np.concatenate(Hte_list), np.concatenate(tgts)),
    }
    with open(pkl, "wb") as f:
        pickle.dump(blob, f)
    print(f"[bench] artifacts cached ({time.time()-t0:.0f}s total)")
    params = jax.tree_util.tree_map(jnp.asarray, blob["params"])
    return (cfg, model, params, blob["W"], blob["b"], blob["Htr"],
            blob["ytr"], blob["Hte"], blob["yte"], blob["targets"])


def update_bench_json(section: str, payload: dict,
                      path: str = "BENCH_serving.json") -> str:
    """Merge one benchmark's machine-readable results into a shared JSON
    file (one top-level key per benchmark, so serve_mixed and
    serve_continuous accumulate into the same ``BENCH_serving.json`` and
    the perf trajectory is diffable across PRs). NaN/inf are serialized as
    null — the file must stay strict-JSON parseable.

    Crash-safe: the merged file is written to a temp sibling and moved
    into place with ``os.replace`` (atomic on POSIX), so a benchmark
    killed mid-write can never leave a truncated ``BENCH_serving.json``
    that silently eats every other benchmark's sections on the next
    merge. A corrupt existing file is loudly rebuilt, not silently.

    Every section is stamped with ``schema_version`` (``SCHEMA_VERSION``)
    and ``generated_at`` (UTC ISO-8601). Pre-existing sections written
    under an older schema are upgraded LOUDLY on merge — stamped with the
    current version plus a ``schema_upgraded_from`` marker — so a mixed
    file always says which sections still carry old-shape fields instead
    of silently mixing schemas."""
    import datetime
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            print(f"[bench] WARNING: existing {path} is unreadable "
                  f"({e}); rebuilding it from this run's section only")
            data = {}
    for name, sec in data.items():
        if not isinstance(sec, dict) or name == section:
            continue
        old = sec.get("schema_version", 1)
        if old < SCHEMA_VERSION:
            print(f"[bench] WARNING: section {name!r} in {path} uses "
                  f"schema v{old}; upgrading to v{SCHEMA_VERSION} "
                  f"(its fields keep the old shape — re-run that "
                  f"benchmark to refresh them)")
            sec["schema_version"] = SCHEMA_VERSION
            sec["schema_upgraded_from"] = old

    def _clean(o):
        if isinstance(o, dict):
            return {k: _clean(v) for k, v in o.items()}
        if isinstance(o, (list, tuple)):
            return [_clean(v) for v in o]
        if isinstance(o, float) and (o != o or o in (float("inf"),
                                                     float("-inf"))):
            return None
        if hasattr(o, "item"):            # numpy scalar
            return _clean(o.item())
        return o

    stamped = dict(payload)
    stamped["schema_version"] = SCHEMA_VERSION
    stamped["generated_at"] = datetime.datetime.now(
        datetime.timezone.utc).isoformat(timespec="seconds")
    data[section] = _clean(stamped)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "w") as f:
            json.dump(data, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)
    return path


def time_fn(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    """Median wall seconds per call (blocks on jax outputs)."""
    ts = []
    for i in range(warmup + iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
            isinstance(out, (jnp.ndarray, tuple, list)) else None
        ts.append(time.perf_counter() - t0)
    ts = sorted(ts[warmup:])
    return ts[len(ts) // 2]


def csv_row(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")


def head_context(W, b, screen=None, **extra):
    """The kwargs dict that builds any registered head via heads.get(name,
    **ctx) — the single construction context benchmarks share."""
    ctx = {"W": W, "b": b, **extra}
    if screen is not None:
        ctx["screen"] = screen
    return ctx


def time_head_per_query(head, H, k: int, n_time: int = 400,
                        warmup: int = 3) -> float:
    """Paper timing protocol: ONE query at a time, wall seconds per query
    through ``head.topk`` (numpy heads run on host; identical per-op
    overheads across methods). Warmup absorbs jit compilation and each
    result is materialized (np.asarray blocks on device arrays) so
    jax-backed heads don't time async dispatch."""
    for i in range(warmup):
        np.asarray(head.topk(H[i:i + 1], k)[0])
    t0 = time.perf_counter()
    for i in range(n_time):
        np.asarray(head.topk(H[i:i + 1], k)[0])
    return (time.perf_counter() - t0) / n_time
