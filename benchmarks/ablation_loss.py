"""Ablation of the paper's loss design (Eq.(6)/(8) hyper-parameters):

  λ  — false-positive weight (paper: 3e-4; λ=0 removes the compute penalty,
       large λ suppresses candidate growth)
  γ  — Lagrange weight on the L̄ ≤ B budget (paper: 10; γ=0 drops the
       budget constraint from the v-step)

Reported per setting: P@5 on held-out contexts and realized L̄ — validates
the paper's intuition that (a) missing a true candidate costs much more than
a wasted inner product (λ ≪ 1) and (b) the budget term keeps L̄ near B.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, get_artifacts
from repro.configs import L2SConfig
from repro.core import fit_l2s, precision_at_k
from repro.core.evaluate import (PerQueryScreen, avg_candidate_size,
                                 exact_topk)


def run(k: int = 5):
    cfg, model, params, W, b, Htr, ytr, Hte, yte, _ = get_artifacts()
    Wd, bd = jnp.asarray(W), jnp.asarray(b)
    Hq = Hte[:1024]
    exact = np.asarray(exact_topk(Wd, bd, jnp.asarray(Hq), k))

    base = L2SConfig(num_clusters=100, budget=40, outer_iters=2,
                     sgd_steps=150)
    settings = [
        ("paper", base),                                        # λ=3e-4, γ=10
        ("lambda0", dataclasses.replace(base, lamb=0.0)),
        ("lambda-big", dataclasses.replace(base, lamb=0.05)),
        ("gamma0", dataclasses.replace(base, gamma=0.0)),
    ]
    for name, l2s_cfg in settings:
        state = fit_l2s(Htr, ytr, cfg.vocab_size, l2s_cfg)
        pq = PerQueryScreen(W, b, state.screen)
        pred = np.stack([pq.topk(Hq[i], k) for i in range(len(Hq))])
        p5 = precision_at_k(pred, exact)
        lbar = avg_candidate_size(state.screen, Hte)
        csv_row(f"ablation/{name}", lbar,
                f"lamb={l2s_cfg.lamb},gamma={l2s_cfg.gamma},"
                f"p5={p5:.3f},lbar={lbar:.1f}")


if __name__ == "__main__":
    run()
