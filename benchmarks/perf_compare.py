"""§Perf companion: baseline vs optimized bound-time comparison across the
full single-pod matrix. Reads results/baselines_16x16.jsonl and
results/opt_16x16.jsonl, writes results/perf_compare.md."""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import csv_row


def _load(f):
    out = {}
    if not os.path.exists(f):
        return out
    for line in open(f):
        r = json.loads(line)
        if "roofline" in r and "error" not in r.get("roofline", {}):
            rl = r["roofline"]
            out[(r["arch"], r["shape"])] = (
                max(rl["compute_s"], rl["memory_s"], rl["collective_s"]),
                rl["dominant"])
    return out


def run():
    base = _load("results/baselines_16x16.jsonl")
    opt = _load("results/opt_16x16.jsonl")
    rows = []
    for k in sorted(base):
        if k in opt and opt[k][0] > 0:
            rows.append((base[k][0] / opt[k][0], k[0], k[1],
                         base[k][0], opt[k][0], base[k][1], opt[k][1]))
    rows.sort(reverse=True)
    lines = ["# Baseline vs §Perf-optimized roofline bound (16×16 mesh)", "",
             "| arch | shape | baseline bound_s (dom) | optimized bound_s "
             "(dom) | × |", "|---|---|---|---|---|"]
    for sp, a, s, b, o, bd, od in rows:
        lines.append(f"| {a} | {s} | {b:.3e} ({bd}) | {o:.3e} ({od}) "
                     f"| {sp:.1f}× |")
        csv_row(f"perf/{a}/{s}", o * 1e6, f"baseline_s={b:.3e},speedup={sp:.2f}x")
    if rows:
        geo = float(np.exp(np.mean([np.log(r[0]) for r in rows])))
        lines.append(f"\ngeomean speedup: **{geo:.2f}×** over {len(rows)} combos")
    with open("results/perf_compare.md", "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"[perf_compare] wrote results/perf_compare.md ({len(rows)} rows)")


if __name__ == "__main__":
    run()
