"""Benchmark harness — one module per paper table + the roofline deliverable.

``PYTHONPATH=src python -m benchmarks.run [table1 table2 ...]``
Each row: ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (ablation_loss, perf_compare, roofline_table,
                            table1_precision, table2_beam, table3_clusters,
                            table4_kmeans, table5_ppl, table6_qualitative)
    tables = {
        "table1": table1_precision.run,
        "table2": table2_beam.run,
        "table3": table3_clusters.run,
        "table4": table4_kmeans.run,
        "table5": table5_ppl.run,
        "table6": table6_qualitative.run,
        "ablation": ablation_loss.run,
        "roofline": roofline_table.run,
        "perf": perf_compare.run,
    }
    wanted = sys.argv[1:] or list(tables)
    print("name,us_per_call,derived")
    for name in wanted:
        t0 = time.time()
        tables[name]()
        print(f"# {name} done in {time.time() - t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
