"""Paper Table 6 analog: qualitative side-by-side decodes — full softmax vs
L2S-screened beam search on the same prompts (the paper shows DE→EN
translations; here token-id sequences from the synthetic corpus with
agreement markers)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, get_artifacts
from repro.configs import L2SConfig
from repro.core import fit_l2s
from repro.data import ZipfMarkovCorpus
from repro.serving import DecodeEngine

N_SAMPLES = 6
MAX_NEW = 16


def run():
    cfg, model, params, W, b, Htr, ytr, *_ = get_artifacts()
    state = fit_l2s(Htr[:40_000], ytr[:40_000], cfg.vocab_size,
                    L2SConfig(num_clusters=100, budget=200, outer_iters=2,
                              sgd_steps=200))
    engine = DecodeEngine(model, params, screen=state.screen,
                          max_len=12 + MAX_NEW)
    c = ZipfMarkovCorpus(cfg.vocab_size, branching=96, seed=0)
    prompts = c.sample_batch(N_SAMPLES, 12, seed=4242)

    same = 0
    for i in range(N_SAMPLES):
        ref = engine.beam_search(prompts[i], beam=5, max_new=MAX_NEW,
                                 head="exact")
        got = engine.beam_search(prompts[i], beam=5, max_new=MAX_NEW,
                                 head="screened")
        a, bseq = ref.tokens[0], got.tokens[0]
        marks = "".join("·" if x == y else "X" for x, y in zip(a, bseq))
        agree = float((a == bseq).mean())
        same += agree == 1.0
        csv_row(f"table6/sample{i}", agree * 100,
                f"full={' '.join(map(str, a[:8]))}...,"
                f"l2s={' '.join(map(str, bseq[:8]))}...,marks={marks}")
    csv_row("table6/summary", same / N_SAMPLES * 100,
            f"identical_decodes={same}/{N_SAMPLES}")


if __name__ == "__main__":
    run()
